//! Observability: step-span tracing + the live metrics registry.
//!
//! The serving loop used to be a black box between "request in" and
//! the exit-time `SchedStats` stderr line. This layer opens it up
//! with two halves that share one design rule — **nothing here may
//! perturb what the engine computes**:
//!
//! 1. **Step-span tracer** ([`Tracer`]): a fixed-capacity ring
//!    ([`SpanRing`]) of typed events recorded from the spec engine,
//!    the exec backends and the coordinator worker, exported on
//!    demand as Chrome trace-event JSON ([`Tracer::chrome_trace`],
//!    `bass serving --trace-out` — loadable in Perfetto, one
//!    swimlane per request).
//! 2. **Live metrics registry** ([`registry::snapshot`]): the
//!    scheduler's counters/gauges plus the tracer's phase totals,
//!    assembled into one JSON snapshot that every exposition path
//!    reads — the TCP `{"cmd":"stats"}` admin command, the periodic
//!    stderr snapshot, the report's per-scenario `observability`
//!    section, and the worker-exit summary line. One source of
//!    truth; the views cannot drift.
//!
//! **Span taxonomy** ([`SpanKind`]): duration spans time the phases
//! of a step — `draft` and `verify` launches, `fused_prefill`,
//! `scatter_bind`, `rebucket` — tagged with exec mode, launch width
//! and launch-vs-padded FLOPs; lifecycle instants mark per-request /
//! per-sequence transitions — `admit`, `retire`, `suspend`,
//! `resume`, `expire`, and per-row `seq_step` outcomes carrying each
//! row's draft `k_i` and accepted count. Engine-wide spans ride
//! trace lane 0; per-request events ride the owning request's lane.
//!
//! **Clock-injection rule**: span timestamps come only from the
//! tracer's own [`Clock`] — wall for real runs, a deterministic
//! manual counter for tests — never from `Instant::now()` at the
//! recording site. Nothing the engine computes (tokens, counters,
//! RNG draws) may depend on a tracer timestamp; that keeps the
//! stub/CI deterministic-counters contract untouched with tracing
//! on, off, or under a test clock (CI proves it by diffing traced
//! vs untraced serving counters bit-for-bit).
//!
//! **Disabled-is-free contract**: a disabled tracer is `None` inside
//! — [`Tracer::begin`] returns `None` without reading any clock, and
//! every record call is an early-return no-op: no allocation, no
//! lock, no time read. The default everywhere is disabled; only
//! `--trace-out` (or a test) turns it on.

mod clock;
pub mod registry;
mod series;
mod span;
mod trace;

use std::sync::{Arc, Mutex};

use crate::runtime::json::Json;

pub use clock::Clock;
pub use series::Series;
pub use span::{SpanEvent, SpanKind, SpanRing};

/// Default ring capacity: generous for a serving scenario (a gate run
/// records a few thousand events) while bounding memory at a few MB.
pub const DEFAULT_RING_CAP: usize = 65_536;

#[derive(Debug)]
struct Core {
    clock: Clock,
    ring: Mutex<SpanRing>,
}

/// Cheaply-cloneable handle to a span ring + clock; `Default` (and
/// [`Tracer::disabled`]) is the free no-op tracer. See the module
/// doc for the taxonomy and the disabled-is-free contract.
#[derive(Debug, Clone, Default)]
pub struct Tracer(Option<Arc<Core>>);

impl Tracer {
    /// The no-op tracer: every call is an early return.
    pub fn disabled() -> Tracer {
        Tracer(None)
    }

    /// Wall-clock tracer (real runs).
    pub fn wall(cap: usize) -> Tracer {
        Tracer(Some(Arc::new(Core {
            clock: Clock::wall(),
            ring: Mutex::new(SpanRing::new(cap)),
        })))
    }

    /// Deterministic-counter-clock tracer (tests).
    pub fn manual(cap: usize) -> Tracer {
        Tracer(Some(Arc::new(Core {
            clock: Clock::manual(),
            ring: Mutex::new(SpanRing::new(cap)),
        })))
    }

    pub fn enabled(&self) -> bool {
        self.0.is_some()
    }

    /// Timestamp for a span about to open — `None`, with no clock
    /// read at all, when tracing is disabled.
    pub fn begin(&self) -> Option<u64> {
        self.0.as_ref().map(|c| c.clock.now_us())
    }

    /// Close a duration span opened by [`Tracer::begin`]. No-op when
    /// disabled (then `started` is `None` too).
    pub fn span(&self, kind: SpanKind, started: Option<u64>, request: u64,
                seq: Option<u64>, mode: &'static str,
                meta: &[(&'static str, f64)]) {
        let (Some(core), Some(t0)) = (self.0.as_deref(), started) else {
            return;
        };
        let t1 = core.clock.now_us();
        core.ring.lock().unwrap().push(SpanEvent {
            kind,
            ts_us: t0,
            dur_us: t1.saturating_sub(t0),
            request,
            seq,
            mode,
            meta: meta.to_vec(),
            index: 0,
        });
    }

    /// Zero-duration lifecycle event. No-op when disabled.
    pub fn instant(&self, kind: SpanKind, request: u64, seq: Option<u64>,
                   mode: &'static str, meta: &[(&'static str, f64)]) {
        let Some(core) = self.0.as_deref() else {
            return;
        };
        let ts = core.clock.now_us();
        core.ring.lock().unwrap().push(SpanEvent {
            kind,
            ts_us: ts,
            dur_us: 0,
            request,
            seq,
            mode,
            meta: meta.to_vec(),
            index: 0,
        });
    }

    /// The held events, oldest first (empty when disabled).
    pub fn snapshot(&self) -> Vec<SpanEvent> {
        match self.0.as_deref() {
            Some(c) => c.ring.lock().unwrap().snapshot(),
            None => Vec::new(),
        }
    }

    /// Oldest events evicted by ring overflow.
    pub fn dropped(&self) -> u64 {
        self.0
            .as_deref()
            .map(|c| c.ring.lock().unwrap().dropped())
            .unwrap_or(0)
    }

    /// Total events ever recorded (held + dropped).
    pub fn recorded(&self) -> u64 {
        self.0
            .as_deref()
            .map(|c| c.ring.lock().unwrap().recorded())
            .unwrap_or(0)
    }

    /// Chrome trace-event JSON of the current ring contents.
    pub fn chrome_trace(&self) -> Json {
        trace::chrome_trace(&self.snapshot(), self.dropped())
    }

    /// Aggregate view for the registry / report `observability`
    /// section: per-kind span counts, per-phase µs totals and time
    /// shares (among the duration spans), and ring accounting.
    pub fn summary(&self) -> Json {
        let events = self.snapshot();
        let mut counts = [0u64; SpanKind::ALL.len()];
        let mut phase_us = [0u64; SpanKind::ALL.len()];
        for e in &events {
            let i = SpanKind::ALL
                .iter()
                .position(|&k| k == e.kind)
                .expect("kind in ALL");
            counts[i] += 1;
            phase_us[i] += e.dur_us;
        }
        let total_us: u64 = SpanKind::ALL
            .iter()
            .enumerate()
            .filter(|(_, k)| k.is_span())
            .map(|(i, _)| phase_us[i])
            .sum();
        let mut span_counts = Vec::new();
        let mut phases = Vec::new();
        let mut shares = Vec::new();
        for (i, kind) in SpanKind::ALL.iter().enumerate() {
            span_counts.push((kind.name(), Json::from(counts[i] as f64)));
            if kind.is_span() {
                phases.push((kind.name(),
                             Json::from(phase_us[i] as f64)));
                let share = if total_us > 0 {
                    Json::from(phase_us[i] as f64 / total_us as f64)
                } else {
                    Json::Null
                };
                shares.push((kind.name(), share));
            }
        }
        Json::obj(vec![
            ("recorded", (self.recorded() as f64).into()),
            ("dropped", (self.dropped() as f64).into()),
            ("span_counts", Json::obj(span_counts)),
            ("phase_us", Json::obj(phases)),
            ("phase_share", Json::obj(shares)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_tracer_is_inert() {
        let t = Tracer::disabled();
        assert!(!t.enabled());
        assert_eq!(t.begin(), None, "no clock read when disabled");
        t.span(SpanKind::Draft, None, 0, None, "stub", &[]);
        t.instant(SpanKind::Admit, 1, None, "stub", &[]);
        assert!(t.snapshot().is_empty());
        assert_eq!(t.recorded(), 0);
    }

    #[test]
    fn manual_tracer_records_deterministic_spans() {
        let t = Tracer::manual(16);
        let t0 = t.begin();
        assert_eq!(t0, Some(0));
        t.span(SpanKind::Draft, t0, 0, None, "stub", &[("k", 4.0)]);
        t.instant(SpanKind::Admit, 3, Some(1), "stub", &[]);
        let evs = t.snapshot();
        assert_eq!(evs.len(), 2);
        assert_eq!(evs[0].kind, SpanKind::Draft);
        assert_eq!(evs[0].ts_us, 0);
        assert_eq!(evs[0].dur_us, 1, "manual clock ticks once per read");
        assert_eq!(evs[1].kind, SpanKind::Admit);
        assert_eq!(evs[1].request, 3);
        assert_eq!(evs[1].ts_us, 2);
    }

    #[test]
    fn summary_counts_and_shares_phases() {
        let t = Tracer::manual(16);
        let t0 = t.begin();
        t.span(SpanKind::Draft, t0, 0, None, "stub", &[]);
        let t1 = t.begin();
        t.span(SpanKind::Verify, t1, 0, None, "stub", &[]);
        t.instant(SpanKind::Retire, 1, Some(0), "stub", &[]);
        let s = t.summary();
        let counts = s.get("span_counts").unwrap();
        assert_eq!(counts.get("draft").unwrap().as_usize().unwrap(), 1);
        assert_eq!(counts.get("verify").unwrap().as_usize().unwrap(), 1);
        assert_eq!(counts.get("retire").unwrap().as_usize().unwrap(), 1);
        let share = s.get("phase_share").unwrap();
        let d = share.get("draft").unwrap().as_f64().unwrap();
        let v = share.get("verify").unwrap().as_f64().unwrap();
        assert!((d + v - 1.0).abs() < 1e-12, "spans share the total");
        assert!(share.opt("retire").is_none(),
                "instants carry no phase share");
    }

    #[test]
    fn empty_summary_has_null_shares_not_nan() {
        let t = Tracer::manual(4);
        let s = t.summary();
        assert!(matches!(s.get("phase_share").unwrap().opt("draft"),
                         Some(Json::Null)));
        let text = s.to_string_pretty();
        assert!(!text.contains("NaN"));
    }
}
