//! Injectable time source for the observability layer.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

/// Monotonic microsecond clock. `Wall` anchors at construction and
/// reads the OS monotonic clock; `Manual` is a deterministic counter
/// that ticks once per read, so unit tests (and anything riding the
/// CI deterministic-counters contract) never observe real time yet
/// still get strictly increasing timestamps.
#[derive(Debug)]
pub enum Clock {
    Wall(Instant),
    Manual(AtomicU64),
}

impl Clock {
    pub fn wall() -> Clock {
        Clock::Wall(Instant::now())
    }

    pub fn manual() -> Clock {
        Clock::Manual(AtomicU64::new(0))
    }

    /// Microseconds since the clock's origin. The manual clock ticks
    /// by one per read, so successive reads never tie.
    pub fn now_us(&self) -> u64 {
        match self {
            Clock::Wall(t0) => t0.elapsed().as_micros() as u64,
            Clock::Manual(n) => n.fetch_add(1, Ordering::Relaxed),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manual_clock_ticks_deterministically() {
        let c = Clock::manual();
        assert_eq!(c.now_us(), 0);
        assert_eq!(c.now_us(), 1);
        assert_eq!(c.now_us(), 2);
    }

    #[test]
    fn wall_clock_is_monotone() {
        let c = Clock::wall();
        let a = c.now_us();
        let b = c.now_us();
        assert!(b >= a);
    }
}
