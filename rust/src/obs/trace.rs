//! Chrome trace-event JSON export (Perfetto-loadable).

use crate::runtime::json::Json;

use super::span::SpanEvent;

/// Non-finite values would render as bare `NaN`/`inf` tokens (invalid
/// JSON) through [`Json::Num`]'s writer; the trace is advisory, so a
/// poisoned metric degrades to `null` rather than a broken file.
fn finite(v: f64) -> Json {
    if v.is_finite() {
        Json::Num(v)
    } else {
        Json::Null
    }
}

/// Render recorded events as a Chrome trace. One process (`pid` 1);
/// `tid` is the owning request id (lane 0 is the engine-wide lane),
/// so Perfetto shows one swimlane per request. Duration spans become
/// complete `X` events, lifecycle instants become thread-scoped `i`
/// events, and every lane gets a `thread_name` metadata record.
/// Events are sorted by start timestamp (ties keep recording order),
/// so `ts` is non-decreasing in file order — the invariant
/// `scripts/check_trace.py` validates.
pub fn chrome_trace(events: &[SpanEvent], dropped: u64) -> Json {
    let mut evs: Vec<&SpanEvent> = events.iter().collect();
    evs.sort_by_key(|e| (e.ts_us, e.index));

    let mut out: Vec<Json> = Vec::with_capacity(evs.len() + 8);
    let mut lanes: Vec<u64> = evs.iter().map(|e| e.request).collect();
    lanes.sort_unstable();
    lanes.dedup();
    for lane in &lanes {
        let name = if *lane == 0 {
            "engine".to_string()
        } else {
            format!("request {lane}")
        };
        out.push(Json::obj(vec![
            ("name", "thread_name".into()),
            ("ph", "M".into()),
            ("pid", 1usize.into()),
            ("tid", (*lane as f64).into()),
            ("args", Json::obj(vec![("name", name.into())])),
        ]));
    }

    for e in evs {
        let mut args: Vec<(&str, Json)> = vec![("mode", e.mode.into())];
        if let Some(s) = e.seq {
            args.push(("seq", finite(s as f64)));
        }
        for (key, v) in &e.meta {
            args.push((key, finite(*v)));
        }
        let mut fields: Vec<(&str, Json)> = vec![
            ("name", e.kind.name().into()),
            ("cat", "bass".into()),
            ("ts", (e.ts_us as f64).into()),
            ("pid", 1usize.into()),
            ("tid", (e.request as f64).into()),
            ("args", Json::obj(args)),
        ];
        if e.kind.is_span() {
            fields.push(("ph", "X".into()));
            fields.push(("dur", (e.dur_us as f64).into()));
        } else {
            fields.push(("ph", "i".into()));
            fields.push(("s", "t".into()));
        }
        out.push(Json::obj(fields));
    }

    Json::obj(vec![
        ("traceEvents", Json::Arr(out)),
        ("displayTimeUnit", "ms".into()),
        ("otherData",
         Json::obj(vec![("dropped_spans", (dropped as f64).into())])),
    ])
}

#[cfg(test)]
mod tests {
    use super::super::span::SpanKind;
    use super::*;

    fn ev(kind: SpanKind, ts: u64, dur: u64, request: u64) -> SpanEvent {
        SpanEvent {
            kind,
            ts_us: ts,
            dur_us: dur,
            request,
            seq: Some(3),
            mode: "stub",
            meta: vec![("k", 4.0)],
            index: ts,
        }
    }

    #[test]
    fn export_sorts_by_ts_and_shapes_events() {
        let events = vec![
            ev(SpanKind::Verify, 20, 5, 0),
            ev(SpanKind::Admit, 10, 0, 7),
            ev(SpanKind::Draft, 12, 6, 0),
        ];
        let j = chrome_trace(&events, 2);
        let arr = j.get("traceEvents").unwrap().as_arr().unwrap();
        // 2 lanes (0 and 7) -> 2 thread_name records + 3 events.
        assert_eq!(arr.len(), 5);
        let data: Vec<&Json> = arr
            .iter()
            .filter(|e| {
                e.get("ph").unwrap().as_str().unwrap() != "M"
            })
            .collect();
        let ts: Vec<f64> = data
            .iter()
            .map(|e| e.get("ts").unwrap().as_f64().unwrap())
            .collect();
        assert_eq!(ts, vec![10.0, 12.0, 20.0], "sorted by start ts");
        let admit = data[0];
        assert_eq!(admit.get("ph").unwrap().as_str().unwrap(), "i");
        assert_eq!(admit.get("tid").unwrap().as_usize().unwrap(), 7);
        let draft = data[1];
        assert_eq!(draft.get("ph").unwrap().as_str().unwrap(), "X");
        assert_eq!(draft.get("dur").unwrap().as_usize().unwrap(), 6);
        assert_eq!(
            draft.get("args").unwrap().get("k").unwrap().as_f64().unwrap(),
            4.0
        );
        let dropped = j
            .get("otherData").unwrap()
            .get("dropped_spans").unwrap()
            .as_usize().unwrap();
        assert_eq!(dropped, 2);
        // The serialized form must parse back (no bare NaN tokens).
        let text = j.to_string_pretty();
        Json::parse(&text).expect("trace round-trips");
    }

    #[test]
    fn non_finite_meta_degrades_to_null() {
        let mut e = ev(SpanKind::Draft, 1, 1, 0);
        e.meta = vec![("bad", f64::NAN)];
        let j = chrome_trace(&[e], 0);
        let text = j.to_string_pretty();
        Json::parse(&text).expect("NaN meta must not poison the file");
        assert!(!text.contains("NaN"));
    }
}
