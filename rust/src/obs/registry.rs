//! The live metrics registry: one assembly point for every
//! exposition path.
//!
//! The registry does not own live counters — the structs that
//! increment them do ([`crate::metrics::SchedStats`] for the
//! scheduler, [`super::Tracer`] for span/phase totals). What it owns
//! is the *snapshot shape*: the TCP `{"cmd":"stats"}` admin reply,
//! the periodic stderr snapshot, the report's `observability`
//! section and the worker-exit summary all read the same
//! [`snapshot`] (or its [`crate::metrics::SchedStats::summary_line`]
//! text rendering), so the views cannot drift from each other or
//! from the numbers the scheduler actually tracked.

use crate::metrics::SchedStats;
use crate::runtime::json::Json;

use super::Tracer;

/// Assemble the registry snapshot: scheduler counters + gauge series
/// under `"sched"`, tracer phase totals under `"spans"` (present
/// only when tracing is enabled — the snapshot stays additive).
pub fn snapshot(stats: &SchedStats, tracer: &Tracer) -> Json {
    let mut pairs = vec![("sched", stats.snapshot())];
    if tracer.enabled() {
        pairs.push(("spans", tracer.summary()));
    }
    Json::obj(pairs)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_is_additive_with_tracing() {
        let stats = SchedStats::default();
        let off = snapshot(&stats, &Tracer::disabled());
        assert!(off.opt("sched").is_some());
        assert!(off.opt("spans").is_none());
        let on = snapshot(&stats, &Tracer::manual(8));
        assert!(on.opt("spans").is_some());
    }
}
