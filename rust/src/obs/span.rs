//! Typed span events and the fixed-capacity ring that stores them.

use std::collections::VecDeque;

/// The span taxonomy (see the [`crate::obs`] module doc). Duration
/// spans ([`SpanKind::is_span`]) time a phase of the serving loop;
/// the rest are zero-duration lifecycle instants.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum SpanKind {
    /// One draft launch across the live batch.
    Draft,
    /// One verify launch across the live batch.
    Verify,
    /// Fused bucket prefill (PAD/PACKED bucket start or re-bucket).
    FusedPrefill,
    /// Per-row scatter prefill into a running bucket.
    ScatterBind,
    /// Per-row KV row copy (fan-out prefill sharing / prefix-cache
    /// reuse) into a running bucket.
    RowCopy,
    /// Live bucket grow/shrink (wraps the backend's fused re-encode).
    Rebucket,
    /// Sequence preempted out of the batch (instant).
    Suspend,
    /// Suspended sequence re-admitted (instant).
    Resume,
    /// Request admitted into the batch (instant).
    Admit,
    /// Sequence retired, its output delivered (instant).
    Retire,
    /// Request expired unserved under a time budget (instant).
    Expire,
    /// Per-row step outcome: draft `k_i` and accepted count (instant).
    SeqStep,
}

impl SpanKind {
    /// Every kind, in a fixed order (stable summary/report layout).
    pub const ALL: [SpanKind; 12] = [
        SpanKind::Draft,
        SpanKind::Verify,
        SpanKind::FusedPrefill,
        SpanKind::ScatterBind,
        SpanKind::RowCopy,
        SpanKind::Rebucket,
        SpanKind::Suspend,
        SpanKind::Resume,
        SpanKind::Admit,
        SpanKind::Retire,
        SpanKind::Expire,
        SpanKind::SeqStep,
    ];

    pub fn name(self) -> &'static str {
        match self {
            SpanKind::Draft => "draft",
            SpanKind::Verify => "verify",
            SpanKind::FusedPrefill => "fused_prefill",
            SpanKind::ScatterBind => "scatter_bind",
            SpanKind::RowCopy => "row_copy",
            SpanKind::Rebucket => "rebucket",
            SpanKind::Suspend => "suspend",
            SpanKind::Resume => "resume",
            SpanKind::Admit => "admit",
            SpanKind::Retire => "retire",
            SpanKind::Expire => "expire",
            SpanKind::SeqStep => "seq_step",
        }
    }

    /// Duration span (Chrome `X` event) vs lifecycle instant (`i`).
    pub fn is_span(self) -> bool {
        matches!(
            self,
            SpanKind::Draft
                | SpanKind::Verify
                | SpanKind::FusedPrefill
                | SpanKind::ScatterBind
                | SpanKind::RowCopy
                | SpanKind::Rebucket
        )
    }
}

/// One recorded event.
#[derive(Debug, Clone)]
pub struct SpanEvent {
    pub kind: SpanKind,
    /// Start timestamp, µs on the owning tracer's clock.
    pub ts_us: u64,
    /// Duration in µs; 0 for instants.
    pub dur_us: u64,
    /// Owning request id — the trace swimlane. 0 = engine-wide (the
    /// coordinator hands out request ids starting at 1).
    pub request: u64,
    /// Sequence id, when the event is per-row.
    pub seq: Option<u64>,
    /// Exec-mode tag (`pad`/`split`/`packed`/`stub`).
    pub mode: &'static str,
    /// Small numeric payload (k, rows, launch FLOPs, accepted, ...).
    pub meta: Vec<(&'static str, f64)>,
    /// Global record index — the total order events were recorded in
    /// (assigned by the ring; survives eviction gaps).
    pub index: u64,
}

/// Fixed-capacity ring: when full, recording evicts the *oldest*
/// event (counted in [`SpanRing::dropped`]) — it never blocks and
/// never grows past the capacity chosen at construction.
#[derive(Debug)]
pub struct SpanRing {
    cap: usize,
    buf: VecDeque<SpanEvent>,
    next_index: u64,
    dropped: u64,
}

impl SpanRing {
    pub fn new(cap: usize) -> SpanRing {
        let cap = cap.max(1);
        SpanRing {
            cap,
            buf: VecDeque::with_capacity(cap),
            next_index: 0,
            dropped: 0,
        }
    }

    /// Record an event (its `index` field is assigned here).
    pub fn push(&mut self, mut ev: SpanEvent) {
        ev.index = self.next_index;
        self.next_index += 1;
        if self.buf.len() == self.cap {
            self.buf.pop_front();
            self.dropped += 1;
        }
        self.buf.push_back(ev);
    }

    /// Events currently held (≤ capacity).
    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// Total events ever recorded (held + dropped).
    pub fn recorded(&self) -> u64 {
        self.next_index
    }

    /// Oldest events evicted to make room.
    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    /// The held events, oldest first.
    pub fn iter(&self) -> impl Iterator<Item = &SpanEvent> {
        self.buf.iter()
    }

    pub fn snapshot(&self) -> Vec<SpanEvent> {
        self.buf.iter().cloned().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(kind: SpanKind, ts: u64) -> SpanEvent {
        SpanEvent {
            kind,
            ts_us: ts,
            dur_us: 0,
            request: 0,
            seq: None,
            mode: "stub",
            meta: Vec::new(),
            index: 0,
        }
    }

    #[test]
    fn ring_keeps_everything_under_capacity() {
        let mut r = SpanRing::new(4);
        for i in 0..3 {
            r.push(ev(SpanKind::Admit, i));
        }
        assert_eq!(r.len(), 3);
        assert_eq!(r.dropped(), 0);
        assert_eq!(r.recorded(), 3);
        let idx: Vec<u64> = r.iter().map(|e| e.index).collect();
        assert_eq!(idx, vec![0, 1, 2]);
    }

    /// The satellite-pinned wraparound contract: on overflow the
    /// *oldest* spans are evicted, the survivors keep their recording
    /// order, and the eviction count is visible.
    #[test]
    fn ring_wraparound_evicts_oldest_and_preserves_order() {
        let mut r = SpanRing::new(4);
        for i in 0..10u64 {
            r.push(ev(SpanKind::SeqStep, 100 + i));
        }
        assert_eq!(r.len(), 4, "capacity is a hard bound");
        assert_eq!(r.dropped(), 6, "oldest six evicted");
        assert_eq!(r.recorded(), 10);
        let idx: Vec<u64> = r.iter().map(|e| e.index).collect();
        assert_eq!(idx, vec![6, 7, 8, 9],
                   "survivors are the newest, in recording order");
        let ts: Vec<u64> = r.iter().map(|e| e.ts_us).collect();
        assert_eq!(ts, vec![106, 107, 108, 109]);
    }

    #[test]
    fn zero_capacity_clamps_to_one() {
        let mut r = SpanRing::new(0);
        r.push(ev(SpanKind::Admit, 1));
        r.push(ev(SpanKind::Retire, 2));
        assert_eq!(r.len(), 1);
        assert_eq!(r.dropped(), 1);
        assert_eq!(r.iter().next().unwrap().kind, SpanKind::Retire);
    }
}
