//! # BASS — Batched Attention-optimized Speculative Sampling
//!
//! A three-layer Rust + JAX + Pallas reproduction of *BASS: Batched
//! Attention-optimized Speculative Sampling* (ACL Findings 2024): a serving
//! engine that performs speculative decoding over a **batch** of sequences,
//! letting every sequence advance past its own reject points (ragged KV
//! state), with the paper's dynamic draft-length heuristic (Algorithm 1)
//! and both ragged-attention execution strategies (BASS-PAD / BASS-SPLIT).
//!
//! Layering (see `DESIGN.md`):
//! * Layer 1/2 (Pallas kernels + JAX model) are AOT-compiled at build time
//!   by `python/compile/aot.py` into HLO-text artifacts; Python is never on
//!   the request path.
//! * This crate is Layer 3: it loads the artifacts through the PJRT C API
//!   (`xla` crate), keeps the KV cache device-resident, and runs the
//!   speculative coordination loop — drafting, verification, acceptance
//!   sampling, draft-length control, batching, serving and evaluation.
//!
//! Entry points:
//! * [`runtime::Engine`] — PJRT client + artifact registry + weights.
//! * [`spec::SpecEngine`] — the BASS decode loop (the paper's §3).
//! * [`baseline::RegularDecoder`] — optimized auto-regressive decoding
//!   (the paper's RD anchor).
//! * [`coordinator::Coordinator`] — request queue, dynamic batcher, server.
//! * [`loadgen`] — open-loop serving load harness (`BENCH_serving.json`).
//! * [`obs`] — step-span tracing + the live metrics registry.
//! * [`eval`] — ROUGE-2 / Pass@K harnesses for the paper's tasks.

pub mod baseline;
pub mod bench_util;
pub mod cli;
pub mod coordinator;
pub mod eval;
pub mod flops;
pub mod kv;
pub mod loadgen;
pub mod metrics;
pub mod obs;
pub mod runtime;
pub mod sampling;
pub mod spec;
pub mod tokenizer;

/// Crate-wide result alias (anyhow-based; PJRT errors are stringly typed).
pub type Result<T> = anyhow::Result<T>;
