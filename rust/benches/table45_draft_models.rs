//! **Tables 4 & 5** — draft-model architecture study: standalone draft
//! accuracy, token acceptance rate, draft-alone per-token latency, and
//! BASS first-sequence PTL, for the three draft variants (A shallow-wide,
//! B deeper, C wider) against the same main model.
//!
//! Paper findings to reproduce in shape: the better-aligned draft (higher
//! acceptance) is not automatically the fastest end-to-end, because its
//! own latency enters every step (Table 4); and a *bigger* draft can be
//! strictly worse on both counts (Table 5).

mod common;

use bass::baseline::{DraftOnlyDecoder, RdConfig};
use bass::bench_util::{artifacts_root, save_result, Table};
use bass::eval::load_code_tasks;
use bass::runtime::json::Json;
use bass::spec::{SpecConfig, SpecEngine};
use bass::tokenizer;

fn main() -> anyhow::Result<()> {
    let engine = common::engine_or_exit("table45");
    let root = artifacts_root();
    let tasks = load_code_tasks(&root)?;
    let n_prob = common::n_problems(6);
    let batches = common::batch_grid(&[1, 2, 4, 8, 16]);
    let drafts = ["draft_a", "draft_b", "draft_c"];
    let max_new = 32;

    // ---- standalone draft quality & PTL -------------------------------------
    let mut head = Table::new(&[
        "draft", "#layer", "#head", "d_model", "#param", "pass@1",
        "accept%",
    ]);
    let mut ptl_table = Table::new(&[
        "draft", "batch", "draft PTL ms", "1st-seq PTL ms (BASS)",
    ]);
    let mut records = Vec::new();

    for d in drafts {
        let info = engine.manifest.model(d)?.clone();
        // Standalone pass@1 with the draft alone (its own sampler).
        let mut pass = 0usize;
        let dd = DraftOnlyDecoder::new(&engine, RdConfig {
            model: d.into(),
            max_new_tokens: max_new,
            ..RdConfig::default()
        });
        for t in tasks.iter().take(n_prob) {
            let res = dd.generate(&[tokenizer::encode(&t.prompt)])?;
            let text = tokenizer::decode(&res.seqs[0].generated);
            if t.passes(&text) {
                pass += 1;
            }
        }
        // Acceptance rate with BASS at batch 2 (stable estimate).
        let spec = SpecEngine::new(&engine, SpecConfig {
            draft_model: d.into(),
            max_new_tokens: max_new,
            ..SpecConfig::default()
        });
        let prompts = vec![tokenizer::encode(&tasks[0].prompt); 2];
        let _ = spec.generate(&prompts)?; // warm
        let mut acc = 0.0;
        for t in tasks.iter().take(n_prob) {
            let prompts = vec![tokenizer::encode(&t.prompt); 2];
            acc += spec.generate(&prompts)?.metrics.acceptance_rate;
        }
        acc /= n_prob as f64;
        head.row(vec![
            d.into(), info.n_layer.to_string(), info.n_head.to_string(),
            info.d_model.to_string(), info.param_count.to_string(),
            format!("{:.1}%", 100.0 * pass as f64 / n_prob as f64),
            format!("{:.1}%", acc * 100.0),
        ]);

        // Per-batch PTLs.
        for &b in &batches {
            let prompts: Vec<Vec<u8>> = (0..b)
                .map(|i| tokenizer::encode(&tasks[i % tasks.len()].prompt))
                .collect();
            let _ = dd.generate(&prompts)?; // warm this batch bucket
            let mut dptl = 0.0;
            let mut first_ptl = 0.0;
            for pi in 0..n_prob.min(3) {
                let dd_run = DraftOnlyDecoder::new(&engine, RdConfig {
                    model: d.into(),
                    max_new_tokens: max_new,
                    seed: pi as u64,
                    ..RdConfig::default()
                });
                let _ = dd_run.generate(&prompts)?; // warm (same seed)
                dptl += dd_run.generate(&prompts)?.metrics.ptl_mean;
                let spec_run = SpecEngine::new(&engine, SpecConfig {
                    draft_model: d.into(),
                    max_new_tokens: max_new,
                    seed: pi as u64,
                    ..SpecConfig::default()
                });
                let _ = spec_run.generate(&prompts)?; // warm (same seed)
                first_ptl += spec_run.generate(&prompts)?.metrics.ptl_first;
            }
            let n = n_prob.min(3) as f64;
            ptl_table.row(vec![
                d.into(), b.to_string(),
                format!("{:.2}", dptl / n * 1e3),
                format!("{:.2}", first_ptl / n * 1e3),
            ]);
            records.push(Json::obj(vec![
                ("draft", d.into()),
                ("batch", b.into()),
                ("draft_ptl_ms", (dptl / n * 1e3).into()),
                ("first_seq_ptl_ms", (first_ptl / n * 1e3).into()),
                ("acceptance", acc.into()),
                ("pass1", (pass as f64 / n_prob as f64).into()),
            ]));
        }
    }

    println!("\nTable 4/5 — draft architecture comparison \
              (paper: A 87.4% / B 88.5% / C 87.2% acceptance; B best \
              stand-alone but slower per step):");
    head.print();
    println!();
    ptl_table.print();
    save_result("table45_draft_models", Json::Arr(records))?;
    Ok(())
}
