//! **Tables 2 & 3** — code generation (synth_humaneval): Pass@Batch and
//! per-token latency for RD vs BASS across batches and precisions.
//! Paper Table 2: CodeGen-16B + 350M draft; Table 3: a 7.8B code model
//! with the Table-4 "A" draft (the `--table3` / BASS_TABLE3=1 variant here
//! extends the batch grid to 16, matching Table 3's extra row).

mod common;

use bass::baseline::{RdConfig, RegularDecoder};
use bass::bench_util::{artifacts_root, save_result, speedup, Table};
use bass::eval::{aggregate, judge, load_code_tasks, Candidate};
use bass::kv::FinishReason;
use bass::runtime::json::Json;
use bass::runtime::Precision;
use bass::spec::{SpecConfig, SpecEngine};
use bass::tokenizer;

// Paper anchors: Table 2 (CodeGen 16B) and Table 3 (7.8B) mean-PTL rows.
const PAPER_T2: &[(&str, usize, f64, f64)] = &[
    ("f32", 1, 23.6, 10.2), ("f32", 2, 26.3, 10.8), ("f32", 4, 27.0, 13.0),
    ("f32", 8, 28.9, 14.9), ("int8", 1, 16.8, 9.3), ("int8", 2, 19.6, 10.1),
    ("int8", 4, 20.4, 11.2), ("int8", 8, 21.9, 14.3),
];
const PAPER_T3: &[(&str, usize, f64, f64)] = &[
    ("f32", 1, 14.4, 4.6), ("f32", 2, 14.6, 5.0), ("f32", 4, 15.1, 5.7),
    ("f32", 8, 16.0, 7.1), ("f32", 16, 16.9, 9.6),
];

fn main() -> anyhow::Result<()> {
    let table3 = std::env::args().any(|a| a == "--table3")
        || std::env::var("BASS_TABLE3").map(|v| v == "1").unwrap_or(false);
    let name = if table3 { "table3" } else { "table2" };
    let engine = common::engine_or_exit(name);
    let root = artifacts_root();
    let tasks = load_code_tasks(&root)?;
    let n_prob = common::n_problems(6);
    let max_new = 32;
    let batches: &[usize] =
        if table3 { &[1, 2, 4, 8, 16] } else { &[1, 2, 4, 8] };

    let mut table = Table::new(&[
        "prec", "batch", "method", "Pass@Batch", "first ms", "last ms",
        "all ms", "speedup(all)", "paper(all)",
    ]);
    let mut records = Vec::new();

    for prec in [Precision::F32, Precision::Int8] {
        for &b in &common::batch_grid(batches) {
            let mut rd_ptl = (0.0, 0.0, 0.0);
            let mut bass_ptl = (0.0, 0.0, 0.0);
            let mut rd_outcomes = Vec::new();
            let mut bass_outcomes = Vec::new();
            for (pi, t) in tasks.iter().take(n_prob).enumerate() {
                let prompts = vec![tokenizer::encode(&t.prompt); b];
                let rd = RegularDecoder::new(&engine, RdConfig {
                    precision: prec,
                    max_new_tokens: max_new,
                    seed: pi as u64,
                    ..RdConfig::default()
                });
                // Identical-seed warm run keeps compiles out of timing.
                let _ = rd.generate(&prompts)?;
                let r = rd.generate(&prompts)?;
                rd_ptl.0 += r.metrics.ptl_first;
                rd_ptl.1 += r.metrics.ptl_last;
                rd_ptl.2 += r.metrics.ptl_mean;
                rd_outcomes.push(judge(&candidates(t, &r.seqs)));

                let spec = SpecEngine::new(&engine, SpecConfig {
                    precision: prec,
                    max_new_tokens: max_new,
                    seed: pi as u64,
                    ..SpecConfig::default()
                });
                let _ = spec.generate(&prompts)?;
                let s = spec.generate(&prompts)?;
                bass_ptl.0 += s.metrics.ptl_first;
                bass_ptl.1 += s.metrics.ptl_last;
                bass_ptl.2 += s.metrics.ptl_mean;
                bass_outcomes.push(judge(&candidates(t, &s.seqs)));
            }
            let n = n_prob as f64;
            let rd_rates = aggregate(&rd_outcomes);
            let bass_rates = aggregate(&bass_outcomes);
            let paper = if table3 { PAPER_T3 } else { PAPER_T2 };
            let paper_str = paper.iter()
                .find(|(p, pb, ..)| *p == prec.as_str() && *pb == b)
                .map(|(_, _, rd, ba)| format!("RD {rd:.1} / BASS {ba:.1}"))
                .unwrap_or_else(|| "-".into());
            table.row(vec![
                prec.as_str().into(), b.to_string(), "RD".into(),
                format!("{:.1}%", rd_rates.pass_batch * 100.0),
                format!("{:.2}", rd_ptl.0 / n * 1e3),
                format!("{:.2}", rd_ptl.1 / n * 1e3),
                format!("{:.2}", rd_ptl.2 / n * 1e3),
                "1.00x".into(), paper_str,
            ]);
            table.row(vec![
                prec.as_str().into(), b.to_string(), "BASS".into(),
                format!("{:.1}%", bass_rates.pass_batch * 100.0),
                format!("{:.2}", bass_ptl.0 / n * 1e3),
                format!("{:.2}", bass_ptl.1 / n * 1e3),
                format!("{:.2}", bass_ptl.2 / n * 1e3),
                speedup(rd_ptl.2, bass_ptl.2), String::new(),
            ]);
            records.push(Json::obj(vec![
                ("precision", prec.as_str().into()),
                ("batch", b.into()),
                ("rd_pass_batch", rd_rates.pass_batch.into()),
                ("bass_pass_batch", bass_rates.pass_batch.into()),
                ("rd_ptl_all_ms", (rd_ptl.2 / n * 1e3).into()),
                ("bass_ptl_first_ms", (bass_ptl.0 / n * 1e3).into()),
                ("bass_ptl_last_ms", (bass_ptl.1 / n * 1e3).into()),
                ("bass_ptl_all_ms", (bass_ptl.2 / n * 1e3).into()),
                ("speedup_all", (rd_ptl.2 / bass_ptl.2.max(1e-12)).into()),
            ]));
        }
    }
    println!("\n{} (synth_humaneval, temp 0.2, top-p 0.95, {n_prob} \
              problems, {max_new} new tokens):",
             if table3 { "Table 3" } else { "Table 2" });
    table.print();
    save_result(name, Json::Arr(records))?;
    Ok(())
}

fn candidates(t: &bass::eval::CodeTask, seqs: &[bass::kv::SeqState])
              -> Vec<Candidate> {
    seqs.iter()
        .map(|s| {
            let text = tokenizer::decode(&s.generated);
            Candidate {
                passes: t.passes(&text),
                text,
                finished: s.finish != FinishReason::Running,
                mean_logp: s.mean_logp(),
            }
        })
        .collect()
}
