//! Shared scaffolding for the paper-table benches.

use bass::bench_util::{artifacts_available, artifacts_root};
use bass::runtime::Engine;

/// Standard bench entry: loads the engine or exits politely.
pub fn engine_or_exit(name: &str) -> Engine {
    if !artifacts_available() {
        eprintln!("[{name}] SKIP: artifacts/ missing — run `make artifacts`");
        std::process::exit(0);
    }
    println!("[{name}] loading engine...");
    Engine::load(&artifacts_root()).expect("engine load")
}

/// Fast mode trims problem counts/batch grids (`BASS_FAST=1`).
pub fn fast_mode() -> bool {
    std::env::var("BASS_FAST").map(|v| v == "1").unwrap_or(false)
}

/// Batch grid for table benches.
pub fn batch_grid(full: &[usize]) -> Vec<usize> {
    if fast_mode() {
        full.iter().copied().filter(|&b| b <= 4).collect()
    } else {
        full.to_vec()
    }
}

pub fn n_problems(full: usize) -> usize {
    if fast_mode() {
        (full / 3).max(2)
    } else {
        full
    }
}
