//! Micro-benchmarks of the runtime hot path: per-phase executable
//! latencies across (batch, Q) — the fixed-vs-variable cost structure that
//! drives all speculative economics on this testbed — plus compile times
//! and H2D/D2H traffic. Feeds the §Perf analysis in EXPERIMENTS.md.

mod common;

use std::time::Instant;

use bass::bench_util::{measure, save_result, Table};
use bass::runtime::json::Json;
use bass::runtime::{Attn, Precision};

fn main() -> anyhow::Result<()> {
    let engine = common::engine_or_exit("microbench");
    let p_cap = engine.manifest.prefill_p;
    let reps = if common::fast_mode() { 5 } else { 20 };

    let mut table = Table::new(&[
        "phase", "model", "prec", "B", "Q", "mean ms", "p90 ms",
        "ms/token",
    ]);
    let mut records = Vec::new();

    let combos: Vec<(&str, Precision, usize, usize)> = vec![
        // Verify-shaped decode calls on the main model.
        ("main", Precision::F32, 1, 1),
        ("main", Precision::F32, 1, 5),
        ("main", Precision::F32, 8, 1),
        ("main", Precision::F32, 8, 5),
        ("main", Precision::F32, 8, 9),
        ("main", Precision::F32, 16, 5),
        ("main", Precision::Int8, 8, 5),
    ];
    for (model, prec, b, q) in combos {
        let toks = vec![65i32; b * p_cap];
        let lens = vec![20i32; b];
        let pre = engine.prefill(model, prec, Attn::Dense, b, &toks, &lens)?;
        let mut caches = Some(pre.caches);
        let step_toks = vec![66i32; b * q];
        let mut seq = 20i32;
        // Warm compile.
        let out = engine.decode(model, prec, Attn::Dense, b, q, &step_toks,
                                &vec![seq; b], caches.take().unwrap())?;
        caches = Some(out.caches);
        seq += 1;
        let s = measure(2, reps, || {
            let out = engine.decode(model, prec, Attn::Dense, b, q,
                                    &step_toks, &vec![seq; b],
                                    caches.take().unwrap())?;
            caches = Some(out.caches);
            seq = (seq + 1).min(180);
            Ok(())
        })?;
        table.row(vec![
            "decode".into(), model.into(), prec.as_str().into(),
            b.to_string(), q.to_string(),
            format!("{:.3}", s.mean() * 1e3),
            format!("{:.3}", s.percentile(0.9) * 1e3),
            format!("{:.3}", s.mean() * 1e3 / (b * q) as f64),
        ]);
        records.push(Json::obj(vec![
            ("phase", "decode".into()), ("model", model.into()),
            ("precision", prec.as_str().into()), ("batch", b.into()),
            ("q", q.into()), ("mean_ms", (s.mean() * 1e3).into()),
        ]));
    }

    // Fused draft call vs K sequential draft calls ---------------------------
    for (b, k) in [(1usize, 4usize), (8, 4), (8, 8)] {
        let toks = vec![65i32; b * p_cap];
        let lens = vec![20i32; b];
        let pre = engine.prefill("draft_a", Precision::F32, Attn::Dense, b,
                                 &toks, &lens)?;
        let mut caches = Some(pre.caches);
        let tokens_in = vec![66i32; b * 2];
        let n_in = vec![1i32; b];
        let uni = vec![0.5f32; b * k];
        let mut seq = 20i32;
        let temps = vec![0.2f32; b];
        let tps = vec![0.95f32; b];
        let out = engine.draft("draft_a", Precision::F32, Attn::Dense, b, k,
                               &tokens_in, &n_in, &vec![seq; b], &uni,
                               &temps, &tps, caches.take().unwrap())?;
        caches = Some(out.caches);
        let s = measure(2, reps, || {
            let out = engine.draft("draft_a", Precision::F32, Attn::Dense,
                                   b, k, &tokens_in, &n_in, &vec![seq; b],
                                   &uni, &temps, &tps,
                                   caches.take().unwrap())?;
            caches = Some(out.caches);
            seq = (seq + 1).min(150);
            Ok(())
        })?;
        table.row(vec![
            format!("draft k={k}"), "draft_a".into(), "f32".into(),
            b.to_string(), k.to_string(),
            format!("{:.3}", s.mean() * 1e3),
            format!("{:.3}", s.percentile(0.9) * 1e3),
            format!("{:.3}", s.mean() * 1e3 / (b * k) as f64),
        ]);
        records.push(Json::obj(vec![
            ("phase", "draft".into()), ("batch", b.into()),
            ("k", k.into()), ("mean_ms", (s.mean() * 1e3).into()),
        ]));
    }

    // Prefill --------------------------------------------------------------
    for b in [1usize, 8] {
        let toks = vec![65i32; b * p_cap];
        let lens = vec![40i32; b];
        let _ = engine.prefill("main", Precision::F32, Attn::Dense, b,
                               &toks, &lens)?;
        let s = measure(1, reps / 2, || {
            let _ = engine.prefill("main", Precision::F32, Attn::Dense, b,
                                   &toks, &lens)?;
            Ok(())
        })?;
        table.row(vec![
            "prefill".into(), "main".into(), "f32".into(), b.to_string(),
            p_cap.to_string(), format!("{:.3}", s.mean() * 1e3),
            format!("{:.3}", s.percentile(0.9) * 1e3),
            format!("{:.3}", s.mean() * 1e3 / (b * p_cap) as f64),
        ]);
    }

    // Pallas-vs-dense artifact latency (the L1 parity subset) --------------
    for (b, q) in [(1usize, 5usize), (8, 5)] {
        let toks = vec![65i32; b * p_cap];
        let lens = vec![20i32; b];
        for attn in [Attn::Dense, Attn::Pallas] {
            let pre = engine.prefill("main", Precision::F32, Attn::Dense, b,
                                     &toks, &lens)?;
            let mut caches = Some(pre.caches);
            let step = vec![66i32; b * q];
            let out = engine.decode("main", Precision::F32, attn, b, q,
                                    &step, &vec![20; b],
                                    caches.take().unwrap())?;
            caches = Some(out.caches);
            let s = measure(1, reps / 2, || {
                let out = engine.decode("main", Precision::F32, attn, b, q,
                                        &step, &vec![21; b],
                                        caches.take().unwrap())?;
                caches = Some(out.caches);
                Ok(())
            })?;
            table.row(vec![
                format!("decode[{}]", if attn == Attn::Pallas {
                    "pallas"
                } else {
                    "dense"
                }),
                "main".into(), "f32".into(), b.to_string(), q.to_string(),
                format!("{:.3}", s.mean() * 1e3),
                format!("{:.3}", s.percentile(0.9) * 1e3),
                format!("{:.3}", s.mean() * 1e3 / (b * q) as f64),
            ]);
            records.push(Json::obj(vec![
                ("phase", "decode_attn_variant".into()),
                ("attn", if attn == Attn::Pallas { "pallas" } else {
                    "dense"
                }.into()),
                ("batch", b.into()), ("q", q.into()),
                ("mean_ms", (s.mean() * 1e3).into()),
            ]));
        }
    }

    println!("\nMicrobench — executable latencies (fixed-vs-variable cost \
              structure):");
    table.print();

    // Compile-time + engine stats summary.
    let st = engine.stats.borrow().clone();
    println!("\ncompiles: {} in {:.1}s  (mean {:.0} ms)", st.compiles,
             st.compile_secs,
             st.compile_secs / (st.compiles.max(1) as f64) * 1e3);
    println!("H2D {:.1} MB, D2H {:.1} MB", st.h2d_bytes as f64 / 1e6,
             st.d2h_bytes as f64 / 1e6);
    let t0 = Instant::now();
    let peak = engine.calibrate_peak_flops(5)?;
    println!("peak {:.1} GFLOP/s (calibrated in {:.1}s)", peak / 1e9,
             t0.elapsed().as_secs_f64());

    records.push(Json::obj(vec![
        ("compiles", (st.compiles as usize).into()),
        ("compile_secs", st.compile_secs.into()),
        ("peak_gflops", (peak / 1e9).into()),
    ]));
    save_result("microbench", Json::Arr(records))?;
    Ok(())
}
