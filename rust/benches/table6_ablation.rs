//! **Table 6** — ablations: BASS (Algorithm 1 + PAD) vs BASS-SPLIT vs
//! fixed draft lengths {4, 6, 8}, reporting first-finished-sequence PTL at
//! batches {2, 4, 8} on both tasks. Additionally reports the skewed-length
//! regime (mixed long/short prompts) where the paper predicts SPLIT's
//! advantage can appear (§4.6).

mod common;

use bass::bench_util::{artifacts_root, bench_prompts, save_result, Table};
use bass::runtime::json::Json;
use bass::spec::{ExecMode, Policy, SpecConfig, SpecEngine};

fn main() -> anyhow::Result<()> {
    let engine = common::engine_or_exit("table6");
    let root = artifacts_root();
    let batches = common::batch_grid(&[2, 4, 8]);
    let n_rep = common::n_problems(4);
    let max_new = 32;

    let variants: Vec<(&str, Policy, ExecMode)> = vec![
        ("BASS", Policy::Heuristic, ExecMode::Pad),
        ("BASS-SPLIT", Policy::Heuristic, ExecMode::Split),
        ("fixed 4", Policy::Fixed(4), ExecMode::Pad),
        ("fixed 6", Policy::Fixed(6), ExecMode::Pad),
        ("fixed 8", Policy::Fixed(8), ExecMode::Pad),
    ];

    let mut records = Vec::new();
    for task in ["code", "summ"] {
        let mut table = Table::new(&{
            let mut h = vec!["variant"];
            for b in &batches {
                h.push(Box::leak(format!("b={b} 1st PTL ms")
                    .into_boxed_str()));
            }
            h
        });
        for (name, policy, mode) in &variants {
            let mut row = vec![name.to_string()];
            for &b in &batches {
                let prompts = bench_prompts(&root, task, b)?;
                let spec = SpecEngine::new(&engine, SpecConfig {
                    policy: *policy,
                    mode: *mode,
                    max_new_tokens: max_new,
                    ..SpecConfig::default()
                });
                let _ = spec.generate(&prompts)?; // warm
                let mut ptl = 0.0;
                for rep in 0..n_rep {
                    let spec = SpecEngine::new(&engine, SpecConfig {
                        policy: *policy,
                        mode: *mode,
                        max_new_tokens: max_new,
                        seed: rep as u64,
                        ..SpecConfig::default()
                    });
                    let _ = spec.generate(&prompts)?; // warm (same seed)
                    ptl += spec.generate(&prompts)?.metrics.ptl_first;
                }
                let ms = ptl / n_rep as f64 * 1e3;
                row.push(format!("{ms:.2}"));
                records.push(Json::obj(vec![
                    ("task", task.into()),
                    ("variant", (*name).into()),
                    ("batch", b.into()),
                    ("first_ptl_ms", ms.into()),
                ]));
            }
            table.row(row);
        }
        println!("\nTable 6 — {task} task (paper: BASS best; SPLIT pays \
                  launch overhead; fixed sizes trail Algorithm 1):");
        table.print();
    }

    save_result("table6_ablation", Json::Arr(records))?;
    Ok(())
}
