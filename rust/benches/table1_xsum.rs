//! **Table 1** — summarization (synth_xsum): ROUGE-2 and per-token latency
//! (first / last / all) for regular decoding vs BASS, across batch sizes
//! and precisions. Paper: OPT 13B + OPT 125M on XSum; here: `main` +
//! `draft_a` on the templated summarization task (DESIGN.md §1).

mod common;

use bass::baseline::{RdConfig, RegularDecoder};
use bass::bench_util::{artifacts_root, save_result, speedup, Table};
use bass::eval::load_summ_tasks;
use bass::eval::rouge2_f1;
use bass::runtime::json::Json;
use bass::runtime::Precision;
use bass::spec::{SpecConfig, SpecEngine};
use bass::tokenizer;

// Paper Table 1 anchors (mean per-token latency, ms) for shape comparison.
const PAPER: &[(&str, usize, f64, f64, f64)] = &[
    // (precision, batch, RD all-ms, BASS all-ms, BASS all-speedup)
    ("f32", 1, 23.4, 10.8, 2.16),
    ("f32", 2, 25.9, 11.0, 2.34),
    ("f32", 4, 27.0, 12.7, 2.13),
    ("int8", 1, 17.4, 8.5, 2.05),
    ("int8", 2, 20.1, 9.3, 2.16),
    ("int8", 4, 21.1, 11.2, 1.88),
    ("int8", 8, 23.5, 14.5, 1.62),
];

fn main() -> anyhow::Result<()> {
    let engine = common::engine_or_exit("table1");
    let root = artifacts_root();
    let tasks = load_summ_tasks(&root)?;
    let n_prob = common::n_problems(6);
    let max_new = 48;

    let mut table = Table::new(&[
        "prec", "batch", "method", "ROUGE-2", "first ms", "last ms",
        "all ms", "speedup(all)", "paper",
    ]);
    let mut records = Vec::new();

    for prec in [Precision::F32, Precision::Int8] {
        for &b in &common::batch_grid(&[1, 2, 4, 8]) {
            let mut rd_ptl = (0.0, 0.0, 0.0);
            let mut rd_rouge = 0.0;
            let mut bass_ptl = (0.0, 0.0, 0.0);
            let mut bass_rouge = 0.0;
            for (pi, t) in tasks.iter().take(n_prob).enumerate() {
                let prompts = vec![tokenizer::encode(&t.prompt); b];
                // RD --------------------------------------------------------
                let rd = RegularDecoder::new(&engine, RdConfig {
                    precision: prec,
                    max_new_tokens: max_new,
                    seed: pi as u64,
                    ..RdConfig::default()
                });
                // Identical-seed warm run: deterministic K-trajectory
                // means the timed run touches only compiled executables.
                let _ = rd.generate(&prompts)?;
                let r = rd.generate(&prompts)?;
                rd_ptl.0 += r.metrics.ptl_first;
                rd_ptl.1 += r.metrics.ptl_last;
                rd_ptl.2 += r.metrics.ptl_mean;
                let text = tokenizer::decode(&r.seqs[0].generated);
                rd_rouge +=
                    rouge2_f1(t.extract_summary(&text), &t.reference);
                // BASS ------------------------------------------------------
                let spec = SpecEngine::new(&engine, SpecConfig {
                    precision: prec,
                    max_new_tokens: max_new,
                    seed: pi as u64,
                    ..SpecConfig::default()
                });
                let _ = spec.generate(&prompts)?;
                let s = spec.generate(&prompts)?;
                bass_ptl.0 += s.metrics.ptl_first;
                bass_ptl.1 += s.metrics.ptl_last;
                bass_ptl.2 += s.metrics.ptl_mean;
                let text = tokenizer::decode(&s.seqs[0].generated);
                bass_rouge +=
                    rouge2_f1(t.extract_summary(&text), &t.reference);
            }
            let n = n_prob as f64;
            let paper = PAPER.iter()
                .find(|(p, pb, ..)| *p == prec.as_str() && *pb == b);
            let paper_str = paper
                .map(|(_, _, rd, ba, sp)| {
                    format!("RD {rd:.1} / BASS {ba:.1} ({sp:.2}x)")
                })
                .unwrap_or_else(|| "-".into());
            table.row(vec![
                prec.as_str().into(), b.to_string(), "RD".into(),
                format!("{:.3}", rd_rouge / n),
                format!("{:.2}", rd_ptl.0 / n * 1e3),
                format!("{:.2}", rd_ptl.1 / n * 1e3),
                format!("{:.2}", rd_ptl.2 / n * 1e3),
                "1.00x".into(), paper_str.clone(),
            ]);
            table.row(vec![
                prec.as_str().into(), b.to_string(), "BASS".into(),
                format!("{:.3}", bass_rouge / n),
                format!("{:.2}", bass_ptl.0 / n * 1e3),
                format!("{:.2}", bass_ptl.1 / n * 1e3),
                format!("{:.2}", bass_ptl.2 / n * 1e3),
                speedup(rd_ptl.2, bass_ptl.2), String::new(),
            ]);
            records.push(Json::obj(vec![
                ("precision", prec.as_str().into()),
                ("batch", b.into()),
                ("rd_rouge2", (rd_rouge / n).into()),
                ("bass_rouge2", (bass_rouge / n).into()),
                ("rd_ptl_all_ms", (rd_ptl.2 / n * 1e3).into()),
                ("bass_ptl_first_ms", (bass_ptl.0 / n * 1e3).into()),
                ("bass_ptl_last_ms", (bass_ptl.1 / n * 1e3).into()),
                ("bass_ptl_all_ms", (bass_ptl.2 / n * 1e3).into()),
                ("speedup_all", (rd_ptl.2 / bass_ptl.2.max(1e-12)).into()),
            ]));
        }
    }
    println!("\nTable 1 (synth_xsum, temp 0.2, top-p 0.95, {n_prob} \
              problems, {max_new} new tokens):");
    table.print();
    save_result("table1_xsum", Json::Arr(records))?;
    Ok(())
}
