//! **Figure 1** — per-token latency and compute utilization of regular
//! decoding (RD), single-sequence speculative decoding (SD, = BASS at
//! B=1) and BASS, across batch sizes.
//!
//! "GPU utilization" is achieved model FLOP/s over a peak calibrated with
//! a large GEMM at startup (the testbed stand-in for the A100 datasheet
//! number the paper uses). Paper shape to reproduce: RD-1 ≈ 0.4%,
//! batched RD up to ≈ 4.8%, BASS up to ≈ 15.8% — i.e. BASS ≫ RD at every
//! batch size, growing with batch.

mod common;

use bass::baseline::{RdConfig, RegularDecoder};
use bass::bench_util::{artifacts_root, bench_prompts, save_result, Table};
use bass::runtime::json::Json;
use bass::spec::{SpecConfig, SpecEngine};

fn main() -> anyhow::Result<()> {
    let engine = common::engine_or_exit("fig1");
    let root = artifacts_root();
    let n_rep = common::n_problems(3);
    // Summarization prompts: ~36-token generations — long enough for
    // speculative amortization to show (code completions EOS after ~8
    // tokens, hiding the draft win; see EXPERIMENTS.md).
    let max_new = 48;

    println!("[fig1] calibrating peak FLOP/s...");
    let peak = engine.calibrate_peak_flops(8)?;
    println!("[fig1] peak ≈ {:.1} GFLOP/s", peak / 1e9);

    let mut table = Table::new(&[
        "method", "batch", "PTL ms", "tokens/s", "utilization",
    ]);
    let mut records = Vec::new();
    let mut add = |method: &str, b: usize, ptl: f64, tps: f64, util: f64,
                   records: &mut Vec<Json>, table: &mut Table| {
        table.row(vec![
            method.into(), b.to_string(), format!("{:.2}", ptl * 1e3),
            format!("{tps:.0}"), format!("{:.2}%", util * 100.0),
        ]);
        records.push(Json::obj(vec![
            ("method", method.into()),
            ("batch", b.into()),
            ("ptl_ms", (ptl * 1e3).into()),
            ("tokens_per_sec", tps.into()),
            ("utilization", util.into()),
            ("peak_gflops", (peak / 1e9).into()),
        ]));
    };

    for &b in &common::batch_grid(&[1, 2, 4, 8, 16]) {
        let prompts = bench_prompts(&root, "summ", b)?;
        // RD ------------------------------------------------------------------
        let rd = RegularDecoder::new(&engine, RdConfig {
            max_new_tokens: max_new,
            ..RdConfig::default()
        });
        let _ = rd.generate(&prompts)?;
        let (mut ptl, mut tps, mut util) = (0.0, 0.0, 0.0);
        for rep in 0..n_rep {
            let rd = RegularDecoder::new(&engine, RdConfig {
                max_new_tokens: max_new,
                seed: rep as u64,
                ..RdConfig::default()
            });
            let _ = rd.generate(&prompts)?; // warm (same seed)
            let r = rd.generate(&prompts)?;
            ptl += r.metrics.ptl_mean;
            tps += r.metrics.tokens_per_sec;
            util += r.flops.utilization(r.metrics.wall_secs
                                        + r.prefill_secs, peak);
        }
        let n = n_rep as f64;
        add("RD", b, ptl / n, tps / n, util / n, &mut records, &mut table);

        // BASS ----------------------------------------------------------------
        let spec = SpecEngine::new(&engine, SpecConfig {
            max_new_tokens: max_new,
            ..SpecConfig::default()
        });
        let _ = spec.generate(&prompts)?;
        let (mut ptl, mut tps, mut util) = (0.0, 0.0, 0.0);
        for rep in 0..n_rep {
            let spec = SpecEngine::new(&engine, SpecConfig {
                max_new_tokens: max_new,
                seed: rep as u64,
                ..SpecConfig::default()
            });
            let _ = spec.generate(&prompts)?; // warm (same seed)
            let r = spec.generate(&prompts)?;
            ptl += r.metrics.ptl_mean;
            tps += r.metrics.tokens_per_sec;
            util += r.flops.utilization(r.metrics.wall_secs
                                        + r.prefill_secs, peak);
        }
        let method = if b == 1 { "SD (BASS b=1)" } else { "BASS" };
        add(method, b, ptl / n, tps / n, util / n, &mut records, &mut table);
    }

    println!("\nFigure 1 — latency & utilization vs batch \
              (paper: RD-1 0.4%, RD-max 4.8%, BASS up to 15.8%):");
    table.print();
    save_result("fig1_utilization", Json::Arr(records))?;
    Ok(())
}
