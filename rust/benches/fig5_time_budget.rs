//! **Figure 5** — accuracy within a wall-clock budget: Pass@First (top
//! mean-logP finished candidate) and Pass@Finished vs batch size at two
//! temperatures, under a budget chosen so that regular decoding cannot
//! finish (paper: 2.5 s for 256 tokens on an A100; here the budget is
//! scaled to 80% of RD's single-sequence completion time).

mod common;

use bass::baseline::{RdConfig, RegularDecoder};
use bass::bench_util::{artifacts_root, save_result, Table};
use bass::eval::{aggregate, judge, load_code_tasks, Candidate};
use bass::kv::FinishReason;
use bass::runtime::json::Json;
use bass::spec::{SpecConfig, SpecEngine};
use bass::tokenizer;

fn main() -> anyhow::Result<()> {
    let engine = common::engine_or_exit("fig5");
    let root = artifacts_root();
    let tasks = load_code_tasks(&root)?;
    let n_prob = common::n_problems(12);
    let max_new = 48;

    // Scale the paper's 2.5 s budget: measure warm RD at B=1 and take 80%
    // of its completion time, so RD provably cannot finish.
    let probe = vec![tokenizer::encode(&tasks[0].prompt)];
    let rd = RegularDecoder::new(&engine, RdConfig {
        max_new_tokens: max_new,
        temperature: 0.8, // discourage early EOS for the probe
        top_p: 1.0,
        ..RdConfig::default()
    });
    let _ = rd.generate(&probe)?;
    let r = rd.generate(&probe)?;
    let budget = 0.8 * r.metrics.ptl_mean * max_new as f64;
    println!("[fig5] RD B=1 needs {:.0} ms for {max_new} tokens -> budget \
              {:.0} ms", r.metrics.ptl_mean * max_new as f64 * 1e3,
             budget * 1e3);

    let mut table = Table::new(&[
        "temp", "batch", "Pass@First", "Pass@Finished", "mean finished",
    ]);
    let mut records = Vec::new();

    for temp in [0.2f32, 0.6] {
        for &b in &common::batch_grid(&[1, 2, 4, 8, 16]) {
            let spec_cfg = SpecConfig {
                temperature: temp,
                max_new_tokens: max_new,
                time_budget_secs: Some(budget),
                ..SpecConfig::default()
            };
            // Warm without budget so compiles don't eat the budget.
            let warm_prompts =
                vec![tokenizer::encode(&tasks[0].prompt); b];
            for warm_seed in 0..3u64 {
                let _ = SpecEngine::new(&engine, SpecConfig {
                    time_budget_secs: None,
                    max_new_tokens: 24,
                    seed: warm_seed,
                    ..spec_cfg.clone()
                }).generate(&warm_prompts)?;
            }

            let mut outcomes = Vec::new();
            let mut finished = 0usize;
            for (pi, t) in tasks.iter().take(n_prob).enumerate() {
                let prompts = vec![tokenizer::encode(&t.prompt); b];
                let spec = SpecEngine::new(&engine, SpecConfig {
                    seed: pi as u64,
                    ..spec_cfg.clone()
                });
                let res = spec.generate(&prompts)?;
                let cands: Vec<Candidate> = res.seqs.iter().map(|s| {
                    let text = tokenizer::decode(&s.generated);
                    Candidate {
                        passes: t.passes(&text),
                        text,
                        finished: s.finish != FinishReason::Running,
                        mean_logp: s.mean_logp(),
                    }
                }).collect();
                finished += cands.iter().filter(|c| c.finished).count();
                outcomes.push(judge(&cands));
            }
            let rates = aggregate(&outcomes);
            table.row(vec![
                format!("{temp}"), b.to_string(),
                format!("{:.1}%", rates.pass_first * 100.0),
                format!("{:.1}%", rates.pass_finished * 100.0),
                format!("{:.1}", finished as f64 / n_prob as f64),
            ]);
            records.push(Json::obj(vec![
                ("temperature", (temp as f64).into()),
                ("batch", b.into()),
                ("budget_ms", (budget * 1e3).into()),
                ("pass_first", rates.pass_first.into()),
                ("pass_finished", rates.pass_finished.into()),
                ("mean_finished",
                 (finished as f64 / n_prob as f64).into()),
            ]));
        }
    }
    println!("\nFigure 5 — accuracy within a time budget RD cannot meet \
              (paper: Pass@Finished up to 61%, Pass@First up to 43%, both \
              rising with batch):");
    table.print();
    save_result("fig5_time_budget", Json::Arr(records))?;
    Ok(())
}
