//! Summarization-service scenario (paper Table 1's workload): run the
//! coordinator + TCP server, fire concurrent summarization requests at it
//! from client threads, and report ROUGE-2 plus queue/batch latency — the
//! distinct-prompts batching case (paper footnote 5).
//!
//! ```bash
//! cargo run --release --example summarize_server -- [n_requests]
//! ```

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::Duration;

use bass::bench_util::artifacts_root;
use bass::coordinator::batcher::BatcherConfig;
use bass::coordinator::{server, Coordinator, CoordinatorConfig};
use bass::eval::{load_summ_tasks, rouge2_f1};
use bass::runtime::json::Json;
use bass::spec::SpecConfig;

fn main() -> anyhow::Result<()> {
    let n_requests: usize = std::env::args()
        .nth(1)
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(8);

    let root = artifacts_root();
    let tasks = load_summ_tasks(&root)?;
    let coord = Arc::new(Coordinator::start(CoordinatorConfig::new(
        root.clone(),
        SpecConfig { max_new_tokens: 48, ..SpecConfig::default() },
        BatcherConfig {
            max_batch: 8,
            window: Duration::from_millis(20),
        },
    ))?);
    println!("engine ready; starting server...");

    let (addr_tx, addr_rx) = std::sync::mpsc::channel();
    let srv = coord.clone();
    std::thread::spawn(move || {
        let _ = server::serve(srv, "127.0.0.1:0", move |a| {
            let _ = addr_tx.send(a);
        });
    });
    let addr = addr_rx.recv()?;
    println!("server on {addr}; sending {n_requests} concurrent requests\n");

    let handles: Vec<_> = (0..n_requests)
        .map(|i| {
            let task = tasks[i % tasks.len()].clone();
            std::thread::spawn(move || -> anyhow::Result<(f64, f64, f64)> {
                let mut stream = TcpStream::connect(addr)?;
                let req = Json::obj(vec![
                    ("prompt", task.prompt.as_str().into()),
                    ("n", 1usize.into()),
                    ("max_new_tokens", 48usize.into()),
                ]);
                stream.write_all(
                    req.to_string_pretty().replace('\n', " ").as_bytes())?;
                stream.write_all(b"\n")?;
                let mut line = String::new();
                BufReader::new(stream).read_line(&mut line)?;
                let j = Json::parse(&line)?;
                anyhow::ensure!(j.get("ok")? == &Json::Bool(true),
                                "server error: {line}");
                let text = j.get("seqs")?.as_arr()?[0]
                    .get("text")?.as_str()?.to_string();
                let summary = text.split('\n').next().unwrap_or("").trim();
                let rouge = rouge2_f1(summary, &task.reference);
                Ok((rouge, j.get("batch_ms")?.as_f64()?,
                    j.get("queue_ms")?.as_f64()?))
            })
        })
        .collect();

    let mut rouges = Vec::new();
    let mut batch_ms = Vec::new();
    let mut queue_ms = Vec::new();
    for h in handles {
        let (r, b, q) = h.join().expect("client thread")?;
        rouges.push(r);
        batch_ms.push(b);
        queue_ms.push(q);
    }
    let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len().max(1) as f64;
    println!("requests      : {n_requests}");
    println!("mean ROUGE-2  : {:.3}", mean(&rouges));
    println!("mean batch ms : {:.1}", mean(&batch_ms));
    println!("mean queue ms : {:.1}", mean(&queue_ms));
    Ok(())
}
