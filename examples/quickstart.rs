//! Quickstart: load the engine, generate a batch of 4 completions with
//! BASS, and compare against regular decoding.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use bass::baseline::{RdConfig, RegularDecoder};
use bass::bench_util::artifacts_root;
use bass::runtime::Engine;
use bass::spec::{SpecConfig, SpecEngine};
use bass::tokenizer;

fn main() -> anyhow::Result<()> {
    let engine = Engine::load(&artifacts_root())?;
    println!("engine up on `{}` with {} artifacts\n",
             engine.platform(), engine.manifest.artifacts.len());

    let prompt = tokenizer::encode(
        "def mul_3(x):\n    # multiplies x by 3\n    return");
    let prompts = vec![prompt; 4];

    // Warm-up (lazy artifact compilation), then a timed run.
    let bass_engine = SpecEngine::new(&engine, SpecConfig::default());
    let _ = bass_engine.generate(&prompts)?;
    let res = bass_engine.generate(&prompts)?;
    println!("BASS (batch=4, Algorithm-1 draft lengths):");
    for (i, s) in res.seqs.iter().enumerate() {
        println!("  [{i}] {:?}", tokenizer::decode(&s.generated));
    }
    println!("  acceptance {:.1}%  tokens/step {:.2}  mean PTL {:.2} ms\n",
             res.metrics.acceptance_rate * 100.0,
             res.metrics.tokens_per_step,
             res.metrics.ptl_mean * 1e3);

    let rd = RegularDecoder::new(&engine, RdConfig::default());
    let _ = rd.generate(&prompts)?;
    let rd_res = rd.generate(&prompts)?;
    println!("Regular decoding (same batch):");
    println!("  mean PTL {:.2} ms  ->  BASS speedup {:.2}x",
             rd_res.metrics.ptl_mean * 1e3,
             rd_res.metrics.ptl_mean / res.metrics.ptl_mean.max(1e-9));
    Ok(())
}
