//! **End-to-end validation driver** (DESIGN.md / EXPERIMENTS.md §E2E):
//! bring up the full serving stack — PJRT engine, speculative BASS decoder,
//! continuous batcher (step-boundary admission, immediate retirement), TCP
//! server — and push a mixed real workload through it: code-completion
//! requests with fan-out (same-prompt batches) interleaved with
//! summarization requests (distinct-prompt batching), plus a streaming
//! request that reads per-step event lines. Reports end-to-end latency
//! percentiles, throughput, acceptance rate and task accuracy, and writes
//! `artifacts/results/serve_e2e.json`.
//!
//! ```bash
//! make artifacts && cargo run --release --example serve_e2e -- [n_rounds]
//! ```

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::{Duration, Instant};

use bass::bench_util::{artifacts_root, save_result};
use bass::coordinator::batcher::BatcherConfig;
use bass::coordinator::{server, Coordinator, CoordinatorConfig};
use bass::eval::{load_code_tasks, load_summ_tasks, rouge2_f1};
use bass::metrics::Summary;
use bass::runtime::json::Json;
use bass::spec::SpecConfig;

struct ClientStats {
    latency: Summary,
    queue_ms: Summary,
    tokens: usize,
    code_pass: usize,
    code_total: usize,
    rouge: Vec<f64>,
}

fn main() -> anyhow::Result<()> {
    let n_rounds: usize = std::env::args()
        .nth(1)
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(6);

    let root = artifacts_root();
    let code_tasks = load_code_tasks(&root)?;
    let summ_tasks = load_summ_tasks(&root)?;

    println!("== BASS end-to-end serving validation ==");
    let t_warm = std::time::Instant::now();
    let coord = Arc::new(Coordinator::start(CoordinatorConfig::new(
        root.clone(),
        SpecConfig { max_new_tokens: 64, ..SpecConfig::default() },
        BatcherConfig {
            max_batch: 8,
            window: Duration::from_millis(10),
        },
    ))?);
    println!("engine ready (prewarm {:.1}s)", t_warm.elapsed().as_secs_f64());
    let (addr_tx, addr_rx) = std::sync::mpsc::channel();
    let srv = coord.clone();
    std::thread::spawn(move || {
        let _ = server::serve(srv, "127.0.0.1:0", move |a| {
            let _ = addr_tx.send(a);
        });
    });
    let addr = addr_rx.recv()?;
    println!("server listening on {addr}");

    // Warm-up round compiles the lazy artifacts.
    {
        let t = &code_tasks[0];
        let _ = request(addr, &t.prompt, 4, 24)?;
        println!("warm-up complete; measuring {n_rounds} rounds\n");
    }

    let t_run = Instant::now();
    let mut stats = ClientStats {
        latency: Summary::default(),
        queue_ms: Summary::default(),
        tokens: 0,
        code_pass: 0,
        code_total: 0,
        rouge: Vec::new(),
    };

    for round in 0..n_rounds {
        // One fan-out code request (batch of 4 recommendations) and two
        // concurrent single summarization requests — mixed traffic.
        let code = code_tasks[round % code_tasks.len()].clone();
        let s1 = summ_tasks[(2 * round) % summ_tasks.len()].clone();
        let s2 = summ_tasks[(2 * round + 1) % summ_tasks.len()].clone();

        let h_code = {
            let prompt = code.prompt.clone();
            std::thread::spawn(move || request(addr, &prompt, 4, 24))
        };
        let h_s1 = {
            let prompt = s1.prompt.clone();
            std::thread::spawn(move || request(addr, &prompt, 1, 48))
        };
        let h_s2 = {
            let prompt = s2.prompt.clone();
            std::thread::spawn(move || request(addr, &prompt, 1, 48))
        };
        let code_resp = h_code.join().expect("join")?;
        let s1_resp = h_s1.join().expect("join")?;
        let s2_resp = h_s2.join().expect("join")?;

        for r in [&code_resp, &s1_resp, &s2_resp] {
            stats.latency.add(r.e2e_ms);
            stats.queue_ms.add(r.queue_ms);
            stats.tokens += r.tokens;
        }
        stats.code_total += 1;
        if code_resp.texts.iter().any(|t| code.passes(t)) {
            stats.code_pass += 1;
        }
        for (resp, task) in [(&s1_resp, &s1), (&s2_resp, &s2)] {
            let summary =
                resp.texts[0].split('\n').next().unwrap_or("").trim();
            stats.rouge.push(rouge2_f1(summary, &task.reference));
        }
        println!("round {round}: code {:.0} ms ({}/{} seqs), summ \
                  {:.0}/{:.0} ms, queue p50 {:.1} ms",
                 code_resp.e2e_ms, code_resp.texts.len(),
                 code_resp.n_requested, s1_resp.e2e_ms, s2_resp.e2e_ms,
                 stats.queue_ms.percentile(0.5));
        if code_resp.texts.len() < code_resp.n_requested {
            println!("  note: fan-out clamped to engine capacity \
                      ({} of {} requested)",
                     code_resp.texts.len(), code_resp.n_requested);
        }
    }

    // Streaming demo: per-step event lines before the final response.
    {
        let t = &code_tasks[0];
        let (deltas, text) = stream_request(addr, &t.prompt, 24)?;
        println!("\nstreaming demo: {} step events, {} chars",
                 deltas, text.len());
    }

    let wall = t_run.elapsed().as_secs_f64();
    let rouge_mean =
        stats.rouge.iter().sum::<f64>() / stats.rouge.len().max(1) as f64;
    let throughput = stats.tokens as f64 / wall;
    println!("\n== results over {n_rounds} rounds ({:.1}s) ==", wall);
    println!("requests        : {}", stats.latency.n());
    println!("e2e latency     : p50 {:.0} ms  p90 {:.0} ms  min {:.0} ms",
             stats.latency.percentile(0.5), stats.latency.percentile(0.9),
             stats.latency.min());
    println!("queue wait      : p50 {:.1} ms", stats.queue_ms.percentile(0.5));
    println!("throughput      : {:.1} tok/s ({} tokens)", throughput,
             stats.tokens);
    println!("code Pass@Batch : {:.0}% ({}/{})",
             100.0 * stats.code_pass as f64 / stats.code_total.max(1) as f64,
             stats.code_pass, stats.code_total);
    println!("summ ROUGE-2    : {rouge_mean:.3}");

    save_result("serve_e2e", Json::obj(vec![
        ("rounds", n_rounds.into()),
        ("requests", stats.latency.n().into()),
        ("latency_p50_ms", stats.latency.percentile(0.5).into()),
        ("latency_p90_ms", stats.latency.percentile(0.9).into()),
        ("queue_p50_ms", stats.queue_ms.percentile(0.5).into()),
        ("throughput_tok_s", throughput.into()),
        ("tokens", stats.tokens.into()),
        ("code_pass_at_batch",
         (stats.code_pass as f64 / stats.code_total.max(1) as f64).into()),
        ("summ_rouge2", rouge_mean.into()),
    ]))?;
    Ok(())
}

/// One streaming request: count event lines, verify the deltas reassemble
/// the final text, and return (n_events, final_text).
fn stream_request(addr: std::net::SocketAddr, prompt: &str,
                  max_new: usize) -> anyhow::Result<(usize, String)> {
    let mut stream = TcpStream::connect(addr)?;
    let req = Json::obj(vec![
        ("prompt", prompt.into()),
        ("max_new_tokens", max_new.into()),
        ("stream", Json::Bool(true)),
    ]);
    stream.write_all(req.to_string_pretty().replace('\n', " ").as_bytes())?;
    stream.write_all(b"\n")?;
    let mut reader = BufReader::new(stream);
    let mut assembled = String::new();
    let mut events = 0usize;
    loop {
        let mut line = String::new();
        reader.read_line(&mut line)?;
        let j = Json::parse(&line)?;
        if j.opt("event").is_some() {
            events += 1;
            assembled.push_str(j.get("delta")?.as_str()?);
            continue;
        }
        anyhow::ensure!(j.get("ok")? == &Json::Bool(true), "server: {line}");
        let text = j.get("seqs")?.as_arr()?[0]
            .get("text")?.as_str()?.to_string();
        anyhow::ensure!(assembled == text,
                        "streamed deltas disagree with final text");
        return Ok((events, text));
    }
}

struct RespStats {
    e2e_ms: f64,
    queue_ms: f64,
    tokens: usize,
    /// Fan-out asked for; fewer returned texts means the engine clamped.
    n_requested: usize,
    texts: Vec<String>,
}

fn request(addr: std::net::SocketAddr, prompt: &str, n: usize,
           max_new: usize) -> anyhow::Result<RespStats> {
    let t0 = Instant::now();
    let mut stream = TcpStream::connect(addr)?;
    let req = Json::obj(vec![
        ("prompt", prompt.into()),
        ("n", n.into()),
        ("max_new_tokens", max_new.into()),
    ]);
    stream.write_all(req.to_string_pretty().replace('\n', " ").as_bytes())?;
    stream.write_all(b"\n")?;
    let mut line = String::new();
    BufReader::new(stream).read_line(&mut line)?;
    let j = Json::parse(&line)?;
    anyhow::ensure!(j.get("ok")? == &Json::Bool(true), "server: {line}");
    let seqs = j.get("seqs")?.as_arr()?;
    Ok(RespStats {
        e2e_ms: t0.elapsed().as_secs_f64() * 1e3,
        queue_ms: j.get("queue_ms")?.as_f64()?,
        n_requested: j.get("n_requested")?.as_usize()?,
        tokens: seqs.iter()
            .map(|s| s.get("n_tokens").and_then(|v| v.as_usize())
                 .unwrap_or(0))
            .sum(),
        texts: seqs.iter()
            .map(|s| Ok(s.get("text")?.as_str()?.to_string()))
            .collect::<anyhow::Result<Vec<_>>>()?,
    })
}
