//! Batched code-recommendation scenario (paper §4.5): for each problem,
//! generate a batch of candidates with BASS, rank by mean-logP, and report
//! Pass@First / Pass@Batch — the "coding assistant returns N suggestions"
//! workload the paper's intro motivates.
//!
//! ```bash
//! cargo run --release --example batch_codegen -- [n_problems] [batch]
//! ```

use bass::bench_util::artifacts_root;
use bass::eval::{aggregate, judge, load_code_tasks, Candidate};
use bass::kv::FinishReason;
use bass::runtime::Engine;
use bass::spec::{SpecConfig, SpecEngine};
use bass::tokenizer;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let n_problems: usize =
        args.get(1).map(|s| s.parse()).transpose()?.unwrap_or(12);
    let batch: usize =
        args.get(2).map(|s| s.parse()).transpose()?.unwrap_or(4);

    let root = artifacts_root();
    let engine = Engine::load(&root)?;
    let tasks = load_code_tasks(&root)?;
    let cfg = SpecConfig { max_new_tokens: 24, ..SpecConfig::default() };
    let spec = SpecEngine::new(&engine, cfg);

    let mut outcomes = Vec::new();
    let mut acc_rates = Vec::new();
    for (i, t) in tasks.iter().take(n_problems).enumerate() {
        let prompts = vec![tokenizer::encode(&t.prompt); batch];
        let res = spec.generate(&prompts)?;
        acc_rates.push(res.metrics.acceptance_rate);
        let cands: Vec<Candidate> = res
            .seqs
            .iter()
            .map(|s| {
                let text = tokenizer::decode(&s.generated);
                Candidate {
                    passes: t.passes(&text),
                    text,
                    finished: s.finish != FinishReason::Running,
                    mean_logp: s.mean_logp(),
                }
            })
            .collect();
        let o = judge(&cands);
        println!("[{i:2}] {:12} pass@first={} pass@batch={} best={:?}",
                 t.task_id, o.pass_first as u8, o.pass_batch as u8,
                 cands.iter().max_by(|a, b| {
                     a.mean_logp.partial_cmp(&b.mean_logp).unwrap()
                 }).map(|c| c.text.trim()).unwrap_or(""));
        outcomes.push(o);
    }
    let r = aggregate(&outcomes);
    let acc = acc_rates.iter().sum::<f64>() / acc_rates.len().max(1) as f64;
    println!("\n{} problems × batch {batch}:", r.n);
    println!("  Pass@First    {:.1}%", r.pass_first * 100.0);
    println!("  Pass@Batch    {:.1}%", r.pass_batch * 100.0);
    println!("  acceptance    {:.1}%", acc * 100.0);
    Ok(())
}
